"""Differential test layer for the training-in-the-loop co-simulation.

The coupling contract, pinned here:

* **The allocation stream is untouched by training.**  For every policy
  (warm and cold, calm and full scenario stack) a co-trained episode's
  durations and per-period allocation stats are *bitwise* equal to the
  duration engine's ``run_scan``, and the period step still traces exactly
  once.
* **Limits recover the decoupled halves.**  With a vanishing period no
  rounds execute and the models stay bitwise at their init (the
  zero-bandwidth limit); with an infinite straggler deadline the executed
  rounds replay plain ``launch/train.py``-style FedAvg (a hand-rolled
  ``make_fl_round_step`` loop on the same batches) to numerical identity;
  with an impossible deadline every round is all-straggler -- learning
  freezes, the allocation stream does not.
* **Engine parity.**  Batch composition is bitwise-irrelevant per seed, the
  sharded/chunked fleet engine matches the flat batch bitwise, and the
  golden ``tests/golden/cotrain_summary.json`` pins the co-trained
  trajectories (regen: ``python tests/golden/regen_cotrain.py``).
* **Service bookkeeping is live.**  ``FLService`` records are driven by the
  episode (arrival/rounds/duration/finished), and a retiring service frees
  its bandwidth slot for the survivors the very next period.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.compat import flat_mesh
from repro.core import network
from repro.fl import cotrain, simulator

NET = network.NetworkConfig(period_s=1.0, mean_clients=5.0, var_clients=2.0)
BASE = dict(n_services_total=3, rounds_required=30, p_arrive=2.0,
            max_periods=50, k_max=12, mean_clients=5.0, var_clients=2.0)
TRAIN = cotrain.TrainSpec(vocab=16, seq_len=6, batch_size=2, eval_batch=8,
                          rounds_cap=2)

FULL_STACK = dict(
    channel_process=scenarios.spec("gauss_markov", rho=0.9),
    arrival_process=scenarios.spec("mmpp", burst=6.0),
    churn_process=scenarios.spec("bernoulli", p_drop=0.1),
)

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden",
                      "cotrain_summary.json")


def _cfg(**kw) -> simulator.SimConfig:
    return simulator.SimConfig(**{**BASE, **kw})


def _init_params(cfg: simulator.SimConfig, train: cotrain.TrainSpec):
    """The exact stacked init the episode derives from its key stream."""
    task = cotrain._build_task(train, cfg.k_max)
    k_init = jax.random.fold_in(jax.random.key(cfg.seed + 7),
                                cotrain.COTRAIN_SALT)
    return jax.vmap(lambda i: task.init(jax.random.fold_in(k_init, i)))(
        jnp.arange(cfg.n_services_total, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# (a) Coupling must not perturb the allocation stream -- every policy.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", simulator.POLICIES)
def test_durations_bitwise_unchanged_by_coupling(policy):
    cfg = _cfg(policy=policy)
    simulator.reset_trace_count()
    co = cotrain.run_cotrain_scan(cfg, TRAIN, NET)
    assert simulator.trace_count() == 1
    ref = simulator.run_scan(cfg, NET)
    assert co["durations"] == ref["durations"]
    assert co["periods"] == ref["periods"]
    assert co["finished"] == ref["finished"]
    for key in ("freq_sum", "objective"):
        np.testing.assert_array_equal(co["history"][key],
                                      ref["history"][key])


def test_duration_parity_warm_start_full_scenario_stack():
    """Warm-started coop under correlated fading + bursty arrivals + churn:
    the policy/scenario carries thread through the co-trained scan exactly
    as through the duration engine's."""
    cfg = _cfg(policy="coop", warm_start=True, rounds_required=25,
               **FULL_STACK)
    simulator.reset_trace_count()
    co = cotrain.run_cotrain_scan(cfg, TRAIN, NET)
    assert simulator.trace_count() == 1
    ref = simulator.run_scan(cfg, NET)
    assert co["durations"] == ref["durations"]
    np.testing.assert_array_equal(co["history"]["freq_sum"],
                                  ref["history"]["freq_sum"])


# ---------------------------------------------------------------------------
# (b) Limits: zero bandwidth / plain FedAvg / all-straggler.
# ---------------------------------------------------------------------------

def test_zero_round_limit_keeps_params_at_init():
    """A vanishing period grants zero rounds everywhere: no training ever
    executes, the stacked params stay at their init (to compilation-context
    rounding of the init draw; the *bitwise* frozenness proof is the exactly
    flat eval curves below), and the (all unfinished) duration stream still
    matches the duration engine."""
    net0 = dataclasses.replace(NET, period_s=1e-6)
    cfg = _cfg(policy="es", max_periods=8)
    co = cotrain.run_cotrain_scan(cfg, TRAIN, net0)
    assert co["trained_rounds"] == [0, 0, 0]
    assert co["clipped_rounds"] == 0
    assert not co["finished"]
    np.testing.assert_allclose(np.asarray(co["params"]),
                               np.asarray(_init_params(cfg, TRAIN)),
                               rtol=1e-6, atol=1e-8)
    h = co["history"]
    for key in ("loss", "acc"):
        np.testing.assert_array_equal(
            h[key], np.broadcast_to(h[key][:1], h[key].shape))
    ref = simulator.run_scan(cfg, net0)
    assert co["durations"] == ref["durations"]


def test_infinite_deadline_recovers_plain_fedavg():
    """With straggler drop disabled, the rounds the co-simulation executes
    are plain FedAvg: a hand-rolled launch/train.py-style loop (same round
    step, same batches, full participation) reproduces the trained params
    and per-period training losses."""
    cfg = simulator.SimConfig(policy="coop", n_services_total=1,
                              rounds_required=10, p_arrive=2.0,
                              max_periods=30, k_max=8, mean_clients=4.0,
                              var_clients=1.0)
    net = network.NetworkConfig(mean_clients=4.0, var_clients=1.0)
    train = dataclasses.replace(TRAIN, deadline_x=float("inf"),
                                rounds_cap=10)
    co = cotrain.run_cotrain_scan(cfg, train, net)
    assert co["finished"] and co["clipped_rounds"] == 0
    assert sum(co["trained_rounds"]) == cfg.rounds_required

    arrivals, counts = simulator._static_draws(cfg, net)
    task = cotrain._build_task(train, cfg.k_max)
    params = _init_params(cfg, train)
    params = jax.tree.map(lambda x: x[0], params)
    weights = (np.arange(cfg.k_max) < int(counts[0])).astype(np.float32)
    h = co["history"]
    # full participation whenever rounds ran
    ran = np.asarray(h["trained"])[:, 0] > 0
    assert np.all(np.asarray(h["participants"])[ran, 0] == int(counts[0]))
    r = 0
    for p in range(co["periods"]):
        losses = []
        for _ in range(int(np.asarray(h["rounds"])[p, 0])):
            batches = task.batch_fn(jnp.int32(0), jnp.int32(r))
            params, metrics = task.round_step(params, batches,
                                              jnp.asarray(weights))
            losses.append(float(metrics["loss"]))
            r += 1
        if losses:
            np.testing.assert_allclose(float(h["train_loss"][p, 0]),
                                       np.mean(losses), rtol=1e-5)
    assert r == cfg.rounds_required
    np.testing.assert_allclose(np.asarray(co["params"])[0],
                               np.asarray(params), rtol=1e-5, atol=1e-6)
    # and the training had real signal: eval loss below the init params'
    init_loss, _ = task.eval_fn(
        jax.tree.map(lambda x: x[0], _init_params(cfg, train)), jnp.int32(0))
    assert h["loss"][co["periods"] - 1, 0] < float(init_loss) - 0.05


def test_all_straggler_rounds_freeze_learning_not_allocation():
    """An impossible deadline drops every client from every round: the new
    zero-participant FedAvg path leaves params untouched (flat eval curves)
    while the simulated rounds -- and therefore the durations -- proceed
    exactly as in the duration engine."""
    cfg = _cfg(policy="pp", rounds_required=25)
    train = dataclasses.replace(TRAIN, deadline_x=1e-3)
    co = cotrain.run_cotrain_scan(cfg, train, NET)
    ref = simulator.run_scan(cfg, NET)
    assert co["durations"] == ref["durations"]
    assert co["finished"]
    h = co["history"]
    assert int(np.asarray(h["participants"]).sum()) == 0
    assert sum(co["trained_rounds"]) > 0          # rounds simulated...
    np.testing.assert_allclose(                   # ...but nothing learned
        np.asarray(co["params"]), np.asarray(_init_params(cfg, train)),
        rtol=1e-6, atol=1e-8)
    np.testing.assert_array_equal(
        h["acc"], np.broadcast_to(h["acc"][:1], h["acc"].shape))


# ---------------------------------------------------------------------------
# (c) Engine parity: batch composition + fleet.
# ---------------------------------------------------------------------------

def test_batch_composition_bitwise_identity():
    cfg = _cfg(policy="es")
    full = cotrain.run_cotrain_batch(cfg, TRAIN, [0, 1, 2], NET)
    alone = cotrain.run_cotrain_batch(cfg, TRAIN, [1], NET)
    for key in ("loss", "acc", "b", "trained"):
        np.testing.assert_array_equal(full["history"][key][1],
                                      alone["history"][key][0])
    np.testing.assert_array_equal(full["durations"][1],
                                  alone["durations"][0])
    single = cotrain.run_cotrain_scan(dataclasses.replace(cfg, seed=2),
                                      TRAIN, NET)
    assert list(full["durations"][2]) == single["durations"]
    assert full["periods"][2] == single["periods"]
    p = single["periods"]
    for key in ("loss", "acc", "train_loss", "b", "f"):
        np.testing.assert_array_equal(full["history"][key][2][:p],
                                      single["history"][key])
    np.testing.assert_array_equal(full["trained_rounds"][2],
                                  single["trained_rounds"])


def test_fleet_bitwise_equals_batch_uneven_chunked():
    """Fleet of 5 on chunk 2 (remainder chunk + pad row): every per-seed
    curve, duration, and final parameter bitwise equals the flat batch; the
    allocation step traces once."""
    cfg = _cfg(policy="es", rounds_required=20)
    seeds = [0, 1, 2, 3, 4]
    simulator.reset_trace_count()
    fleet = cotrain.run_cotrain_fleet(
        cfg, TRAIN, seeds, NET,
        mesh=flat_mesh(1, axis_name="seeds"), chunk_size=2)
    assert simulator.trace_count() == 1
    assert fleet["fleet"] == {"n_devices": 1, "mesh_axis": "seeds",
                              "chunk": 2, "n_chunks": 3, "padded_to": 6}
    batch = cotrain.run_cotrain_batch(cfg, TRAIN, seeds, NET)
    np.testing.assert_array_equal(fleet["durations"], batch["durations"])
    np.testing.assert_array_equal(fleet["trained_rounds"],
                                  batch["trained_rounds"])
    np.testing.assert_array_equal(fleet["clipped_rounds"],
                                  batch["clipped_rounds"])
    for key in cotrain._CURVE_KEYS:
        np.testing.assert_array_equal(fleet["history"][key],
                                      batch["history"][key])
    np.testing.assert_array_equal(np.asarray(fleet["params"]),
                                  np.asarray(batch["params"]))
    for a, b in zip(fleet["services"], batch["services"]):
        assert a == b


# ---------------------------------------------------------------------------
# (d) Live FLService bookkeeping + bandwidth release on retirement.
# ---------------------------------------------------------------------------

def test_service_retirement_frees_bandwidth_next_period():
    """Seed chosen so both services share the band, then one finishes first:
    its FLService record flips finished, its slot drops to b = 0, and the
    survivor's share snaps from B/2 to the full budget the next period."""
    cfg = simulator.SimConfig(policy="es", n_services_total=2,
                              rounds_required=60, p_arrive=3.0,
                              max_periods=80, k_max=12, mean_clients=5.0,
                              var_clients=2.0, seed=3)
    co = cotrain.run_cotrain_scan(cfg, TRAIN, NET)
    arrivals, counts = simulator._static_draws(cfg, NET)
    svcs = co["services"]
    assert [s.service_id for s in svcs] == [0, 1]
    assert [s.n_clients for s in svcs] == [int(c) for c in counts]
    assert [s.arrived_period for s in svcs] == [int(a) for a in arrivals]
    assert [s.periods_active for s in svcs] == co["durations"]
    assert all(s.finished and s.rounds_done == 60 for s in svcs)

    h = co["history"]
    active = np.asarray(h["active"]).astype(bool)
    b = np.asarray(h["b"])
    both = active[:, 0] & active[:, 1]
    assert both.any(), "test premise: services must overlap"
    # Equal-Service splits exactly while both are live ...
    np.testing.assert_array_equal(b[both], 5.0)
    # ... and the retiring service's bandwidth is re-cleared to the survivor
    # on the very next period.
    t = int(np.where(active[:, 0])[0][-1])
    assert active[t + 1, 1] and not active[t + 1, 0]
    assert b[t + 1, 0] == 0.0
    assert b[t + 1, 1] == 10.0


# ---------------------------------------------------------------------------
# (e) Golden regression (regen: python tests/golden/regen_cotrain.py).
# ---------------------------------------------------------------------------

def test_golden_cotrain_summary():
    with open(GOLDEN) as fp:
        golden = json.load(fp)
    cfg_kw = dict(golden["config"])
    train = cotrain.TrainSpec(**golden["train"])
    net = network.NetworkConfig(**golden["net"])
    for pol, exp in golden["policies"].items():
        out = cotrain.run_cotrain_batch(
            simulator.SimConfig(policy=pol, **cfg_kw), train,
            golden["seeds"], net)
        np.testing.assert_array_equal(out["durations"], exp["durations"])
        np.testing.assert_array_equal(out["trained_rounds"],
                                      exp["trained_rounds"])
        np.testing.assert_array_equal(out["periods"], exp["periods"])
        final = np.asarray([out["history"]["loss"][i, p - 1]
                            for i, p in enumerate(out["periods"])])
        np.testing.assert_allclose(final, exp["final_loss"], rtol=1e-4)
        final_acc = np.asarray([out["history"]["acc"][i, p - 1]
                                for i, p in enumerate(out["periods"])])
        np.testing.assert_allclose(final_acc, exp["final_acc"],
                                   rtol=0, atol=1e-4)


# ---------------------------------------------------------------------------
# (f) Zoo task + spec validation.
# ---------------------------------------------------------------------------

def test_zoo_task_trains_with_duration_parity():
    """A smoke-scaled zoo transformer rides the same co-simulation: the
    duration stream still matches the duration engine and the eval metrics
    are well-formed."""
    net = network.NetworkConfig(mean_clients=3.0, var_clients=1.0)
    cfg = simulator.SimConfig(policy="es", n_services_total=2,
                              rounds_required=4, p_arrive=2.0,
                              max_periods=16, k_max=5, mean_clients=3.0,
                              var_clients=1.0)
    train = cotrain.TrainSpec(task="zoo", arch="gemma3-1b", seq_len=8,
                              batch_size=2, eval_batch=2, rounds_cap=2,
                              client_lr=0.1)
    co = cotrain.run_cotrain_scan(cfg, train, net)
    ref = simulator.run_scan(cfg, net)
    assert co["durations"] == ref["durations"]
    h = co["history"]
    assert np.all(np.isfinite(h["loss"]))
    assert np.all((h["acc"] >= 0.0) & (h["acc"] <= 1.0))
    assert sum(co["trained_rounds"]) > 0


def test_train_spec_validation():
    with pytest.raises(ValueError, match="rounds_cap"):
        cotrain.TrainSpec(rounds_cap=0)
    with pytest.raises(ValueError, match="deadline_x"):
        cotrain.TrainSpec(deadline_x=0.0)
    with pytest.raises(ValueError, match="unknown train task"):
        cotrain._build_task(cotrain.TrainSpec(task="nope"), 4)
    with pytest.raises(ValueError, match="encoder-decoder"):
        cotrain._build_task(
            cotrain.TrainSpec(task="zoo", arch="seamless-m4t-large-v2"), 4)
    with pytest.raises(ValueError, match="comp_levels"):
        cotrain.TrainSpec(comp_levels=())
    with pytest.raises(ValueError, match="comp_levels"):
        cotrain.TrainSpec(comp_levels=["topk"])      # list, not tuple
    with pytest.raises(ValueError, match="compression"):
        cotrain.TrainSpec(comp_levels=("topk", "gzip"))
    with pytest.raises(ValueError, match="comp_policy"):
        cotrain.TrainSpec(comp_policy="sometimes")
    with pytest.raises(ValueError, match="topk_frac"):
        cotrain.TrainSpec(topk_frac=0.0)
    with pytest.raises(ValueError, match="comp_threshold"):
        cotrain.TrainSpec(comp_threshold=0.0)


# ---------------------------------------------------------------------------
# (g) The closed compression->allocation loop.
# ---------------------------------------------------------------------------

def test_topk_compression_shortens_durations():
    """Pricing topk into the dynamic s^UT column makes every round cheaper:
    the compressed episode's durations never exceed the dense stream's, the
    priced multiplier shows up verbatim in the ``ul_mult`` history, and the
    whole episode still traces exactly once."""
    cfg = _cfg(policy="es")
    train = dataclasses.replace(TRAIN, compression="topk", topk_frac=0.05,
                                index_bits=16)
    simulator.reset_trace_count()
    co = cotrain.run_cotrain_scan(cfg, train, NET)
    assert simulator.trace_count() == 1
    ref = simulator.run_scan(cfg, NET)
    assert all(c <= r for c, r in zip(co["durations"], ref["durations"]))
    assert sum(co["durations"]) < sum(ref["durations"])
    # ul_mult records the priced ratio: 0.05 * (32 + 16) / 32 = 0.075
    np.testing.assert_allclose(np.asarray(co["history"]["ul_mult"]), 0.075)
    assert np.all(np.asarray(co["history"]["comp_id"]) == 1)


def test_all_none_levels_bitwise_equal_dense_spec():
    """An explicit all-dense level assignment compiles to the identical
    no-compression episode: the gating is static, so the traced graph (and
    every output) is bitwise the baseline spec's."""
    cfg = _cfg(policy="coop")
    dense = cotrain.run_cotrain_scan(cfg, TRAIN, NET)
    leveled = cotrain.run_cotrain_scan(
        cfg, dataclasses.replace(TRAIN, comp_levels=("none",) * 3), NET)
    assert leveled["durations"] == dense["durations"]
    for key in ("loss", "acc", "train_loss", "b"):
        np.testing.assert_array_equal(leveled["history"][key],
                                      dense["history"][key])
    np.testing.assert_array_equal(np.asarray(leveled["params"]),
                                  np.asarray(dense["params"]))
    np.testing.assert_array_equal(np.asarray(leveled["history"]["ul_mult"]),
                                  1.0)


def test_mixed_levels_price_per_service():
    """Heterogeneous static levels: each service slot carries its own s^UT
    multiplier into the allocator, constant over the episode."""
    cfg = _cfg(policy="es")
    train = dataclasses.replace(
        TRAIN, comp_levels=("none", "topk", "int8"), topk_frac=0.05,
        index_bits=16)
    co = cotrain.run_cotrain_scan(cfg, train, NET)
    ul = np.asarray(co["history"]["ul_mult"])
    np.testing.assert_allclose(ul[:, 0], 1.0)
    np.testing.assert_allclose(ul[:, 1], 0.075)
    np.testing.assert_allclose(ul[:, 2], 0.25)
    assert np.all(np.isfinite(np.asarray(co["history"]["loss"])))


def test_adaptive_compression_reacts_to_tight_bandwidth():
    """The adaptive controller starts dense (reactive: the first period has
    no allocation to judge), then compresses exactly the services whose
    share fell below comp_threshold x fair, re-pricing their s^UT the next
    period."""
    cfg = _cfg(policy="pp")
    train = dataclasses.replace(TRAIN, compression="topk", topk_frac=0.05,
                                index_bits=16, comp_policy="adaptive",
                                comp_threshold=1.5)
    co = cotrain.run_cotrain_scan(cfg, train, NET)
    h = co["history"]
    comp_id = np.asarray(h["comp_id"])
    ul = np.asarray(h["ul_mult"])
    assert np.all(comp_id[0] == 0), "first period must apply dense"
    assert comp_id.max() == 1, "threshold 1.5x fair must trigger under pp"
    # the applied multiplier is a pure function of the applied level
    np.testing.assert_allclose(ul[comp_id == 1], 0.075)
    np.testing.assert_allclose(ul[comp_id == 0], 1.0)
    # the controller's decision matches the previous period's shares
    active = np.asarray(h["active"]).astype(bool)
    b = np.asarray(h["b"])
    for t in range(1, co["periods"]):
        n_act = max(int(active[t - 1].sum()), 1)
        fair = NET.total_bandwidth_mhz / n_act
        want = active[t - 1] & (b[t - 1] < train.comp_threshold * fair)
        np.testing.assert_array_equal(comp_id[t] == 1, want)


def test_error_feedback_episode_trains_and_keeps_allocation():
    """EF residuals ride the episode carry: the allocation stream is
    untouched (bitwise vs the same spec without EF -- EF changes params,
    never s^UT), metrics stay finite, and training makes progress."""
    cfg = _cfg(policy="es")
    train = dataclasses.replace(TRAIN, compression="topk", topk_frac=0.25,
                                error_feedback=True)
    co = cotrain.run_cotrain_scan(cfg, train, NET)
    plain = cotrain.run_cotrain_scan(
        cfg, dataclasses.replace(train, error_feedback=False), NET)
    assert co["durations"] == plain["durations"]
    for key in ("b", "f", "ul_mult", "rounds"):
        np.testing.assert_array_equal(co["history"][key],
                                      plain["history"][key])
    h = co["history"]
    assert np.all(np.isfinite(h["loss"])) and np.all(np.isfinite(h["train_loss"]))
    assert np.all((h["acc"] >= 0.0) & (h["acc"] <= 1.0))
    assert sum(co["trained_rounds"]) > 0
    # EF genuinely changes the learning trajectory under lossy compression
    assert not np.array_equal(np.asarray(co["params"]),
                              np.asarray(plain["params"]))
