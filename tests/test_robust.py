"""Byzantine-robust aggregation + adversarial-participant chaos.

Pins the robustness contract of ``fl.aggregation`` and ``chaos.clients`` /
``chaos.bids``:

* **Registry.**  The aggregator catalogue is string-keyed like
  ``core.policy``: unknown names and unknown options raise, ``TrainSpec``
  validates its ``aggregator`` field at construction.
* **Mask discipline.**  Every registered aggregator ignores dropped
  (weight-0) clients entirely -- even NaN/Inf garbage -- returns exact zero
  on an all-straggler round, and is jit- and vmap-safe.  The robust
  aggregators additionally survive NaN updates from *participating* clients;
  plain FedAvg demonstrably does not (that asymmetry is the point).
* **Breakdown separation.**  Under the tuned 20% sign-flip cohort the
  co-trained episode breaks plain FedAvg (accuracy collapses) while
  trimmed-mean / median hold within ``invariants.ROBUST_ACC_DROP`` -- and
  the attacked episode's *allocation* stream stays bitwise equal to
  ``run_scan`` (the attack only touches uploads, never the market).
* **Replay.**  Attack plans and bid deviations are deterministic functions
  of ``(seed, period, channel)`` (PR 8 chaos schedule), so every adversarial
  trajectory replays bitwise; audited bid deviations never gain more than
  the Eq. 31 truthfulness bound (``invariants.regret_bounded``).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.chaos import invariants
from repro.chaos.bids import BidChaos, audit_deviation, deviate_bid
from repro.chaos.clients import ATTACKS, AttackSpec, ClientChaos, attack_fn
from repro.core import auction, network
from repro.fl import aggregation, cotrain, server, simulator

ROBUST = ("trimmed_mean", "median", "norm_clip", "krum", "multi_krum")


def _deltas(rng, n_clients: int):
    return {
        "w": jnp.asarray(rng.normal(size=(n_clients, 3, 2)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n_clients, 4)).astype(np.float32)),
    }


# ---------------------------------------------------------------------------
# Registry contract.
# ---------------------------------------------------------------------------

def test_registry_catalogue():
    names = aggregation.available()
    assert set(names) == {"fedavg", *ROBUST}
    for name in names:
        assert callable(aggregation.get_aggregator(name))


def test_unknown_aggregator_and_option_raise():
    with pytest.raises(ValueError, match="unknown aggregator"):
        aggregation.get_aggregator("geometric_median")
    with pytest.raises(ValueError, match="options"):
        aggregation.get_aggregator("trimmed_mean", banana=1)
    with pytest.raises(ValueError, match="trim_frac"):
        aggregation.get_aggregator("trimmed_mean", trim_frac=0.5)
    with pytest.raises(ValueError, match="clip_norm"):
        aggregation.get_aggregator("norm_clip", clip_norm=-1.0)


def test_trainspec_rejects_unknown_aggregator():
    with pytest.raises(ValueError, match="unknown aggregator"):
        cotrain.TrainSpec(aggregator="nope")


# ---------------------------------------------------------------------------
# Mask discipline, per aggregator.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(aggregation.available()))
def test_dropped_client_garbage_never_contributes(name):
    """Poisoning every weight-0 client with NaN must not move ANY
    aggregator's output (dropped clients are outside the participant set,
    whatever the reduction)."""
    rng = np.random.default_rng(3)
    deltas = _deltas(rng, 8)
    weights = jnp.asarray([1.0, 0.0, 2.0, 1.0, 0.0, 1.0, 0.5, 0.0])
    dropped = np.asarray(weights) == 0.0
    poison = jax.tree.map(
        lambda d: jnp.where(
            jnp.asarray(dropped).reshape((-1,) + (1,) * (d.ndim - 1)),
            jnp.float32(np.nan), d),
        deltas)
    agg = aggregation.get_aggregator(name)
    base, poisoned = agg(deltas, weights), agg(poison, weights)
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]),
                                      np.asarray(poisoned[k]))
        assert np.all(np.isfinite(np.asarray(poisoned[k])))


@pytest.mark.parametrize("name", sorted(aggregation.available()))
def test_all_dropped_round_is_exact_zero(name):
    rng = np.random.default_rng(4)
    deltas = _deltas(rng, 5)
    agg = aggregation.get_aggregator(name)
    out = agg(deltas, jnp.zeros((5,)))
    for k in out:
        np.testing.assert_array_equal(np.asarray(out[k]), 0.0)


@pytest.mark.parametrize("name", ROBUST)
def test_robust_aggregators_survive_participant_nan(name):
    """A NaN update from a *participating* client: robust aggregators mask
    it out of the participant set and stay finite."""
    rng = np.random.default_rng(5)
    deltas = _deltas(rng, 7)
    deltas = jax.tree.map(lambda d: d.at[2].set(jnp.nan), deltas)
    weights = jnp.ones((7,))
    out = aggregation.get_aggregator(name)(deltas, weights)
    for k in out:
        assert np.all(np.isfinite(np.asarray(out[k]))), (name, k)


def test_fedavg_poisoned_by_participant_nan():
    """The asymmetry the robust catalogue exists for: plain FedAvg averages
    a participating NaN straight into the model."""
    rng = np.random.default_rng(5)
    deltas = jax.tree.map(lambda d: d.at[2].set(jnp.nan), _deltas(rng, 7))
    out = server.fedavg_round(deltas, jnp.ones((7,)))
    assert any(not np.all(np.isfinite(np.asarray(out[k]))) for k in out)


def test_median_matches_numpy_reference():
    rng = np.random.default_rng(6)
    deltas = _deltas(rng, 9)
    weights = jnp.asarray([1, 1, 0, 1, 1, 1, 0, 1, 1], jnp.float32)
    out = aggregation.get_aggregator("median")(deltas, weights)
    part = np.asarray(weights) > 0
    for k, d in deltas.items():
        ref = np.median(np.asarray(d)[part], axis=0)
        np.testing.assert_allclose(np.asarray(out[k]), ref, rtol=1e-6,
                                   atol=1e-7)


def test_trimmed_mean_matches_reference():
    rng = np.random.default_rng(7)
    deltas = _deltas(rng, 10)
    weights = jnp.ones((10,))
    out = aggregation.get_aggregator("trimmed_mean", trim_frac=0.2)(
        deltas, weights)
    for k, d in deltas.items():
        srt = np.sort(np.asarray(d), axis=0)
        ref = srt[2:-2].mean(axis=0)      # t = floor(0.2 * 10) = 2 per side
        np.testing.assert_allclose(np.asarray(out[k]), ref, rtol=1e-5,
                                   atol=1e-6)


def test_krum_picks_honest_cluster():
    """Krum scores by distance to nearest neighbors: a lone far outlier is
    never selected, and the chosen update is one of the honest cluster's."""
    rng = np.random.default_rng(8)
    honest = rng.normal(size=(6, 4)).astype(np.float32) * 0.1
    deltas = {"w": jnp.asarray(np.vstack([honest, 100.0 + honest[:1]]))}
    out = aggregation.get_aggregator("krum", byz_f=1)(
        deltas, jnp.ones((7,)))
    dists = np.linalg.norm(honest - np.asarray(out["w"]), axis=-1)
    assert float(dists.min()) < 1e-6          # exactly one honest update
    assert float(np.asarray(out["w"]).max()) < 50.0


def test_norm_clip_bounds_the_aggregate():
    rng = np.random.default_rng(9)
    deltas = {"w": jnp.asarray(rng.normal(size=(5, 8)).astype(np.float32))}
    deltas["w"] = deltas["w"].at[0].multiply(1e4)   # one inflated client
    out = aggregation.get_aggregator("norm_clip", clip_norm=1.0)(
        deltas, jnp.ones((5,)))
    assert float(np.linalg.norm(np.asarray(out["w"]))) <= 1.0 + 1e-5


@pytest.mark.parametrize("name", sorted(aggregation.available()))
def test_aggregators_jit_and_vmap(name):
    rng = np.random.default_rng(10)
    agg = aggregation.get_aggregator(name)
    deltas = _deltas(rng, 6)
    weights = jnp.asarray([1, 1, 0, 1, 1, 1], jnp.float32)
    jitted = jax.jit(agg)(deltas, weights)
    for k, v in agg(deltas, weights).items():
        np.testing.assert_allclose(np.asarray(jitted[k]), np.asarray(v),
                                   rtol=1e-6, atol=1e-7)
    stacked = jax.tree.map(lambda d: jnp.stack([d, 2 * d]), deltas)
    batched = jax.vmap(agg, in_axes=(0, None))(stacked, weights)
    for k in batched:
        assert np.all(np.isfinite(np.asarray(batched[k])))
        np.testing.assert_allclose(np.asarray(batched[k][0]),
                                   np.asarray(jitted[k]), rtol=1e-6,
                                   atol=1e-7)


# ---------------------------------------------------------------------------
# Attack catalogue: validation, determinism, semantics.
# ---------------------------------------------------------------------------

def test_attack_spec_validation():
    with pytest.raises(ValueError, match="attack"):
        AttackSpec(attack="teleport")
    with pytest.raises(ValueError, match="byz_frac"):
        AttackSpec(byz_frac=1.5)
    assert AttackSpec().attack in ATTACKS


def test_client_plan_is_deterministic_and_seeded():
    spec = AttackSpec(attack="sign_flip", byz_frac=0.2, seed=3)
    a = ClientChaos(spec).plan(8, 3, 10)
    b = ClientChaos(spec).plan(8, 3, 10)
    np.testing.assert_array_equal(a, b)
    c = ClientChaos(dataclasses.replace(spec, seed=4)).plan(8, 3, 10)
    assert not np.array_equal(a, c)
    # marked fraction tracks byz_frac
    frac = float(np.mean(a))
    assert 0.05 < frac < 0.4


def test_attack_fn_semantics():
    spec = AttackSpec(attack="sign_flip", scale=2.0)
    deltas = {"w": jnp.ones((4, 3))}
    weights = jnp.ones((4,))
    byz = jnp.asarray([True, False, False, True])
    flipped, w2 = attack_fn(spec)(deltas, weights, byz)
    np.testing.assert_array_equal(np.asarray(flipped["w"][0]), -2.0)
    np.testing.assert_array_equal(np.asarray(flipped["w"][1]), 1.0)
    np.testing.assert_array_equal(np.asarray(w2), np.asarray(weights))

    nan_d, _ = attack_fn(AttackSpec(attack="nan"))(deltas, weights, byz)
    assert np.all(np.isnan(np.asarray(nan_d["w"][0])))
    assert np.all(np.isfinite(np.asarray(nan_d["w"][1])))

    _, w3 = attack_fn(AttackSpec(attack="inflate_weight", scale=10.0))(
        deltas, weights, byz)
    np.testing.assert_array_equal(np.asarray(w3), [10.0, 1.0, 1.0, 10.0])


# ---------------------------------------------------------------------------
# Bid chaos: deviations replay and never beat the truthfulness bound.
# ---------------------------------------------------------------------------

def _bid_setup():
    svc, _ = network.sample_services(jax.random.key(0), 5)
    return svc, network.B_TOTAL_MHZ


def test_bid_deviations_regret_bounded():
    svc, B = _bid_setup()
    rows = BidChaos(seed=11).run(svc, B, n_trials=4)
    gate = invariants.regret_bounded(rows)
    assert gate["ok"], gate
    assert gate["n_audited"] == 4
    for r in rows:
        assert r["deviation"] in ("overbid", "shade", "free_ride")
        assert np.isfinite(r["u_truthful"]) and np.isfinite(r["u_deviated"])


def test_bid_chaos_replays_bitwise():
    svc, B = _bid_setup()
    a = BidChaos(seed=5).run(svc, B, n_trials=3)
    b = BidChaos(seed=5).run(svc, B, n_trials=3)
    assert a == b
    c = BidChaos(seed=6).run(svc, B, n_trials=3)
    assert a != c


def test_deviate_bid_shapes_and_validation():
    svc, _ = _bid_setup()
    truthful = auction.uniform_truthful_bids(svc, 5, 0.5)
    dev = deviate_bid(truthful, 1, "overbid", 2.0)
    np.testing.assert_allclose(np.asarray(dev.demands)[1],
                               np.asarray(truthful.demands)[1] * 2.0)
    np.testing.assert_array_equal(np.asarray(dev.demands)[0],
                                  np.asarray(truthful.demands)[0])
    np.testing.assert_array_equal(np.asarray(dev.prices),
                                  np.asarray(truthful.prices))
    free = deviate_bid(truthful, 2, "free_ride", 0.0)
    np.testing.assert_array_equal(np.asarray(free.demands)[2][1:], 0.0)
    with pytest.raises(ValueError, match="deviation"):
        deviate_bid(truthful, 0, "bribe", 1.0)


def test_audit_deviation_reports_regret():
    svc, B = _bid_setup()
    row = audit_deviation(svc, B, 0, "shade", 0.5)
    assert row["gain"] == pytest.approx(row["u_deviated"] - row["u_truthful"])
    assert row["gain"] <= row["delta_bound"] + 1e-3
    assert row["regret"] == max(0.0, row["gain"])


# ---------------------------------------------------------------------------
# Robustness gates (unit).
# ---------------------------------------------------------------------------

def test_gates_unit():
    assert invariants.accuracy_bounded(0.6, 0.55)["ok"]
    assert not invariants.accuracy_bounded(0.6, 0.2)["ok"]
    assert not invariants.accuracy_bounded(0.6, float("nan"))["ok"]
    assert invariants.params_finite({"w": jnp.ones((3,))})["ok"]
    assert not invariants.params_finite(
        {"w": jnp.asarray([1.0, jnp.nan])})["ok"]
    with pytest.raises(AssertionError, match="accuracy"):
        invariants.assert_robust(
            {"accuracy": invariants.accuracy_bounded(0.6, 0.1)})


# ---------------------------------------------------------------------------
# Co-trained integration: the tuned separation scenario (see EXPERIMENTS.md
# §Adversarial robustness).  One cached episode per aggregator.
# ---------------------------------------------------------------------------

NET = network.NetworkConfig(period_s=1.0, mean_clients=9.0, var_clients=1.0)
BASE = dict(n_services_total=2, rounds_required=40, p_arrive=2.0,
            max_periods=60, k_max=12, mean_clients=9.0, var_clients=1.0)
TRAIN = cotrain.TrainSpec(vocab=16, seq_len=6, batch_size=2, eval_batch=32,
                          rounds_cap=3)
ATTACK = AttackSpec(attack="sign_flip", byz_frac=0.2, scale=20.0, seed=1)


@functools.lru_cache(maxsize=None)
def _episode(aggregator: str | None):
    """Final mean accuracy (+ params finiteness, durations) for one tuned
    episode; ``None`` = clean fedavg baseline."""
    cfg = simulator.SimConfig(policy="coop", **BASE)
    if aggregator is None:
        out = cotrain.run_cotrain_scan(cfg, TRAIN, NET)
    else:
        spec = dataclasses.replace(TRAIN, aggregator=aggregator,
                                   trim_frac=0.25, byz_f=3)
        out = cotrain.run_cotrain_scan(cfg, spec, NET, attack=ATTACK)
    acc = float(np.asarray(out["history"]["acc"])[out["periods"] - 1].mean())
    finite = invariants.params_finite(out["params"])["ok"]
    return acc, finite, tuple(out["durations"])


def test_fedavg_breaks_under_sign_flip():
    clean, _, _ = _episode(None)
    attacked, _, _ = _episode("fedavg")
    assert clean - attacked > 2 * invariants.ROBUST_ACC_DROP, (clean, attacked)


@pytest.mark.parametrize("name", ["trimmed_mean", "median"])
def test_robust_aggregators_hold_under_sign_flip(name):
    clean, _, _ = _episode(None)
    attacked, finite, _ = _episode(name)
    gate = invariants.accuracy_bounded(clean, attacked)
    assert gate["ok"], gate
    assert finite


def test_attack_never_touches_the_allocation_stream():
    """Durations of the attacked episode are bitwise the duration engine's:
    the adversary corrupts uploads, not the market."""
    ref = simulator.run_scan(simulator.SimConfig(policy="coop", **BASE), NET)
    for agg in (None, "fedavg", "trimmed_mean", "median"):
        _, _, durations = _episode(agg)
        assert list(durations) == ref["durations"], agg


@pytest.mark.parametrize("policy,warm", [("coop", True), ("es", False)])
@pytest.mark.parametrize("name", sorted(aggregation.available()))
def test_trace_once_per_aggregator_policy_combo(name, policy, warm):
    """Every aggregator rides the same single-trace episode scan, warm or
    cold, and never perturbs the duration stream."""
    cfg = simulator.SimConfig(policy=policy, warm_start=warm,
                              n_services_total=2, rounds_required=8,
                              p_arrive=2.0, max_periods=10, k_max=8,
                              mean_clients=5.0, var_clients=1.0)
    net = network.NetworkConfig(period_s=1.0, mean_clients=5.0,
                                var_clients=1.0)
    spec = dataclasses.replace(
        cotrain.TrainSpec(vocab=16, seq_len=6, batch_size=2, eval_batch=8,
                          rounds_cap=2),
        aggregator=name)
    simulator.reset_trace_count()
    co = cotrain.run_cotrain_scan(cfg, spec, net)
    assert simulator.trace_count() == 1
    ref = simulator.run_scan(cfg, net)
    assert co["durations"] == ref["durations"]
