"""Sharding-rule matrix coverage: every (arch x shape) cell's param, batch,
and cache shardings are well-formed on abstract production meshes (fast --
no device allocation, no compile)."""
import jax
import jax.numpy as jnp
import math
import pytest

from repro import configs
from repro.compat import AxisType, abstract_mesh
from repro.distributed import sharding
from repro.models import registry


def _meshes():
    at = (AxisType.Auto,)
    return [
        abstract_mesh((16, 16), ("data", "model"), axis_types=at * 2),
        abstract_mesh((2, 16, 16), ("pod", "data", "model"), axis_types=at * 3),
    ]


def _check_divisible(tree_sds, tree_sh, mesh):
    for (path, leaf), sh in zip(
        jax.tree_util.tree_leaves_with_path(tree_sds),
        jax.tree.leaves(tree_sh, is_leaf=lambda x: hasattr(x, "spec")),
    ):
        spec = sh.spec
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            n = 1
            for ax in (axes if isinstance(axes, tuple) else (axes,)):
                n *= mesh.shape[ax]
            assert leaf.shape[dim] % n == 0, (
                jax.tree_util.keystr(path), leaf.shape, spec)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
@pytest.mark.parametrize("serve_2d", [False, True])
def test_param_shardings_divisible(arch, serve_2d):
    cfg = configs.get_config(arch)
    params = registry.param_specs(cfg)
    for mesh in _meshes():
        sh = sharding.param_shardings(cfg, params, mesh, serve_2d=serve_2d)
        _check_divisible(params, sh, mesh)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_batch_and_cache_shardings_divisible(arch):
    cfg = configs.get_config(arch)
    model = registry.build_model(cfg)
    for mesh in _meshes():
        for shape_name in registry.SHAPES:
            if not registry.supports(cfg, shape_name):
                continue
            specs = registry.input_specs(cfg, shape_name)
            if "batch" in specs:
                sh = sharding.batch_shardings(cfg, specs["batch"], mesh)
                _check_divisible(specs["batch"], sh, mesh)
            if "cache" in specs:
                sh = sharding.cache_shardings(cfg, specs["cache"], mesh)
                _check_divisible(specs["cache"], sh, mesh)


def test_split_kv_cache_sharding_when_heads_indivisible():
    """command-r: 8 kv heads < model=16 -> the cache shards its seq dim over
    model (split-KV decode) instead of replicating 21 GB/chip."""
    cfg = configs.get_config("command-r-35b")
    model = registry.build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768))
    mesh = _meshes()[0]
    sh = sharding.cache_shardings(cfg, cache, mesh)
    assert sh["k"].spec[2] == "model", sh["k"].spec
    assert sh["k"].spec[1] == "data", sh["k"].spec


def test_param_bytes_per_chip_fit_serving():
    """Serving layout: every arch's bf16 weights fit 16 GB/chip on the
    single-pod mesh (the KV cache is accounted separately per cell)."""
    mesh = _meshes()[0]
    for arch in configs.ARCH_NAMES:
        cfg = configs.get_config(arch)
        params = registry.param_specs(cfg)
        sh = sharding.param_shardings(cfg, params, mesh, serve_2d=True)
        per_chip = 0
        for leaf, s in zip(jax.tree.leaves(params),
                           jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))):
            n_shards = 1
            for axes in s.spec:
                if axes is None:
                    continue
                for ax in (axes if isinstance(axes, tuple) else (axes,)):
                    n_shards *= mesh.shape[ax]
            per_chip += math.prod(leaf.shape) * 2 / n_shards  # bf16
        assert per_chip < 16e9, (arch, per_chip / 1e9)
