"""Fault-tolerance contracts: resume-loss bounds of ``resumable_loop``,
elastic remesh planning at awkward device counts, the repo-wide
mutable-default-argument audit that the ``fault.resumable_loop`` fix
(``policy=RestartPolicy()`` evaluated once at def time) motivated, and the
aggregation-registry mask audit (every registered aggregator -- present and
future -- must ``where``-mask its client-axis reductions)."""
import dataclasses
import importlib
import inspect
import pkgutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.distributed import elastic, fault


def _make_step(log):
    def step(state, t):
        log.append(t)
        return state * jnp.float32(1.0001) + jnp.float32(t)
    return step


def _clean_run(tmp_path, n_steps, save_every):
    mgr = CheckpointManager(tmp_path / "clean")
    log = []
    final = fault.resumable_loop(
        _make_step(log), jnp.float32(1.0), n_steps, mgr,
        fault.RestartPolicy(save_every=save_every))
    assert log == list(range(n_steps))
    return final


@pytest.mark.parametrize("fail_at,expected_replayed", [
    (7, [6]),       # off-boundary: newest checkpoint is step 6, replay t=6
    (6, []),        # on-boundary: checkpoint exactly at the crash, replay 0
])
def test_resumable_loop_replay_bound(tmp_path, fail_at, expected_replayed):
    """A crashed-and-restarted loop resumes bit-identically to a clean run
    and re-executes at most ``save_every - 1`` steps."""
    n_steps, save_every = 10, 3
    clean = _clean_run(tmp_path, n_steps, save_every)

    mgr = CheckpointManager(tmp_path / "crash")
    policy = fault.RestartPolicy(save_every=save_every)
    log = []
    with pytest.raises(RuntimeError, match="injected"):
        fault.resumable_loop(_make_step(log), jnp.float32(1.0), n_steps, mgr,
                             policy, fail_at=fail_at)
    assert log == list(range(fail_at))
    resumed_log = []
    final = fault.resumable_loop(_make_step(resumed_log), jnp.float32(1.0),
                                 n_steps, mgr, policy)
    replayed = [t for t in resumed_log if t < fail_at]
    assert replayed == expected_replayed
    assert len(replayed) <= save_every - 1
    assert resumed_log[-1] == n_steps - 1
    # bit-identical, not merely close: deterministic step + exact restore
    assert np.array_equal(np.asarray(final), np.asarray(clean))


def test_resumable_loop_post_step_crash_bound(tmp_path):
    """``fail_phase="post_step"`` dies in the torn-write window: the step
    completed but its state was never committed.  Resume must replay it from
    the last checkpoint, land bit-identical to a clean run, and lose at most
    ``save_every`` steps of work (one more than the pre-step bound -- the
    finished-but-unsaved step itself)."""
    n_steps, save_every, fail_at = 10, 3, 5
    clean = _clean_run(tmp_path, n_steps, save_every)

    mgr = CheckpointManager(tmp_path / "crash_post")
    policy = fault.RestartPolicy(save_every=save_every)
    log = []
    with pytest.raises(RuntimeError, match="after step 5 .pre-commit."):
        fault.resumable_loop(_make_step(log), jnp.float32(1.0), n_steps, mgr,
                             policy, fail_at=fail_at, fail_phase="post_step")
    # The crashing step DID run before the process died.
    assert log == list(range(fail_at + 1))
    # Newest surviving checkpoint predates the crash (step 3, after t=2).
    assert mgr.all_steps()[-1] == 3
    resumed_log = []
    final = fault.resumable_loop(_make_step(resumed_log), jnp.float32(1.0),
                                 n_steps, mgr, policy)
    replayed = [t for t in resumed_log if t <= fail_at]
    assert replayed == [3, 4, 5]
    assert len(replayed) <= save_every
    assert np.array_equal(np.asarray(final), np.asarray(clean))


def test_resumable_loop_rejects_unknown_fail_phase(tmp_path):
    with pytest.raises(ValueError, match="fail_phase"):
        fault.resumable_loop(_make_step([]), jnp.float32(1.0), 2,
                             CheckpointManager(tmp_path / "x"),
                             fail_at=1, fail_phase="mid_step")


def test_restart_policy_default_not_shared():
    """Regression for the def-time-evaluated ``policy=RestartPolicy()``
    default: the signature default must be None (fresh instance per call),
    not one shared mutable dataclass."""
    default = inspect.signature(fault.resumable_loop).parameters["policy"]
    assert default.default is None


# -- elastic remesh planning -------------------------------------------------

def test_plan_service_remesh_non_power_of_two_model_parallel():
    plan = elastic.plan_service_remesh(12, 9, model_parallel=6)
    assert plan["before"] == {"data": 2, "model": 6}
    # 9 devices can't hold model=6; halving lands on 3 (9 = 3 x 3)
    assert plan["after"] == {"data": 3, "model": 3}
    assert plan["model_parallel_changed"] is True
    for side in ("before", "after"):
        assert plan[side]["data"] * plan[side]["model"] in (12, 9)


def test_plan_service_remesh_shrink_below_model_parallel():
    plan = elastic.plan_service_remesh(32, 4, model_parallel=16)
    assert plan["before"] == {"data": 2, "model": 16}
    assert plan["after"] == {"data": 1, "model": 4}
    assert plan["after"]["model"] <= 4
    assert plan["model_parallel_changed"] is True


def test_plan_service_remesh_degenerate_single_device():
    plan = elastic.plan_service_remesh(16, 1, model_parallel=16)
    assert plan["after"] == {"data": 1, "model": 1}


# -- repo-wide mutable-default audit ----------------------------------------

def _is_mutable_default(value) -> bool:
    if isinstance(value, (list, dict, set, bytearray)):
        return True
    # A non-frozen dataclass instance as a default is the same trap:
    # one shared instance whose fields any caller can mutate.
    return (dataclasses.is_dataclass(value)
            and not type(value).__dataclass_params__.frozen)


def _iter_repro_callables():
    import repro
    for info in pkgutil.walk_packages(repro.__path__, "repro."):
        try:
            mod = importlib.import_module(info.name)
        except Exception:  # pragma: no cover - optional deps stay optional
            continue
        for _, fn in inspect.getmembers(mod, inspect.isfunction):
            if fn.__module__ == info.name:
                yield fn
        for _, cls in inspect.getmembers(mod, inspect.isclass):
            if cls.__module__ != info.name or dataclasses.is_dataclass(cls):
                continue   # dataclass fields are audited by dataclasses itself
            for _, fn in inspect.getmembers(cls, inspect.isfunction):
                if fn.__qualname__.startswith(cls.__name__):
                    yield fn


def test_no_mutable_defaults_under_src_repro():
    """The audit behind the resumable_loop fix: no function or method in
    the package may default an argument to a shared mutable instance."""
    offenders, scanned = [], 0
    for fn in _iter_repro_callables():
        scanned += 1
        try:
            sig = inspect.signature(fn)
        except (ValueError, TypeError):
            continue
        for name, param in sig.parameters.items():
            if param.default is not inspect.Parameter.empty and \
                    _is_mutable_default(param.default):
                offenders.append(f"{fn.__module__}.{fn.__qualname__}({name})")
    assert scanned > 100, "audit walked suspiciously few callables"
    assert not offenders, f"mutable defaults found: {offenders}"


# -- registry-wide aggregator mask audit -------------------------------------

def test_every_registered_aggregator_masks_the_client_axis():
    """Behavioral audit over the WHOLE aggregation registry (including
    entries future PRs add): any aggregator that reduces over the client
    axis without a ``where`` mask -- a bare ``sum(w * d)``, an unmasked
    ``sort``/``median`` -- is flagged here, because NaN garbage from
    weight-0 clients would leak through the reduction.  Three probes per
    entry, several client counts each: (1) poisoning every dropped client
    with NaN must not move the output bitwise, (2) the output must stay
    finite, (3) the all-dropped round must aggregate to exactly zero."""
    import jax
    import jax.numpy as jnp

    from repro.fl import aggregation

    offenders = []
    for name in aggregation.available():
        agg = aggregation.get_aggregator(name)
        for n_clients, seed in ((3, 0), (6, 1), (11, 2)):
            rng = np.random.default_rng(seed)
            deltas = {
                "w": jnp.asarray(
                    rng.normal(size=(n_clients, 4)).astype(np.float32)),
                "b": jnp.asarray(
                    rng.normal(size=(n_clients, 2, 3)).astype(np.float32)),
            }
            weights = jnp.asarray(
                (rng.uniform(size=n_clients) > 0.4).astype(np.float32))
            dropped = np.asarray(weights) == 0.0
            if not dropped.any():
                weights = weights.at[0].set(0.0)
                dropped = np.asarray(weights) == 0.0
            poison = jax.tree.map(
                lambda d: jnp.where(
                    jnp.asarray(dropped).reshape(
                        (-1,) + (1,) * (d.ndim - 1)),
                    jnp.float32(np.nan), d),
                deltas)
            base, poisoned = agg(deltas, weights), agg(poison, weights)
            for k in base:
                if not np.array_equal(np.asarray(base[k]),
                                      np.asarray(poisoned[k])):
                    offenders.append(
                        f"{name}: dropped-client NaN moved leaf {k!r} "
                        f"(C={n_clients})")
                if not np.all(np.isfinite(np.asarray(poisoned[k]))):
                    offenders.append(
                        f"{name}: non-finite output leaf {k!r} "
                        f"(C={n_clients})")
            empty = agg(deltas, jnp.zeros((n_clients,)))
            for k in empty:
                if np.any(np.asarray(empty[k]) != 0.0):
                    offenders.append(
                        f"{name}: all-dropped round not exactly zero "
                        f"({k!r}, C={n_clients})")
    assert not offenders, (
        "aggregators reducing over the client axis without a mask:\n  "
        + "\n  ".join(offenders))
