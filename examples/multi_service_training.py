"""Example 2: REAL multi-service federated training under allocated bandwidth.

Two FL services (a reduced gemma-2b and a reduced xlstm-1.3b) train
concurrently on synthetic-but-learnable data; every period the selected
``AllocationPolicy`` (here cooperative DISBA, resolved through the
``core.policy`` registry -- any of coop/selfish/ec/es/pp works) splits the
10 MHz between them, the intra-service solver splits each share across
clients, the round-time model converts bandwidth into wall-clock rounds, and
each service runs that many honest FedAvg rounds (with straggler deadlines).
``--intra-backend pallas`` routes the per-client split through the
``kernels/bisect_alloc`` TPU kernel (interpret mode on CPU).

This is a thin wrapper over the production driver:

  PYTHONPATH=src python examples/multi_service_training.py
(equivalent to python -m repro.launch.train --services gemma-2b,xlstm-1.3b
 --policy coop --periods 3 --checkpoint-dir /tmp/fl_ckpt)
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    sys.argv = [sys.argv[0],
                "--services", "gemma-2b,xlstm-1.3b",
                "--policy", "coop",
                "--intra-backend", "reference",
                "--periods", "3",
                "--clients", "4",
                "--checkpoint-dir", "/tmp/fl_quickstart_ckpt"]
    train.main()
