"""Scenario stress test: every policy through a churn-heavy edge network.

The paper evaluates its five allocation regimes under i.i.d. channels and
smooth Poisson arrivals.  This example re-runs all of them through the
scenario engine's worst weather -- temporally-correlated Rayleigh fading on
top of Gauss-Markov shadowing, bursty MMPP arrivals, and Gilbert client
churn with long outages -- and compares average service durations against
the calm (paper-default) scenario.  Each (policy, scenario) cell is one
compiled `run_batch` call over several seeds.

  PYTHONPATH=src python examples/scenario_stress.py
"""
import dataclasses

import numpy as np

from repro import scenarios
from repro.fl import simulator

SEEDS = [0, 1, 2, 3]

calm = simulator.SimConfig(
    n_services_total=4, rounds_required=400, p_arrive=3.0,
    mean_clients=15.0, var_clients=10.0, max_periods=400, k_max=32,
)

stormy = dataclasses.replace(
    calm,
    # deep fades that persist across periods, on slowly-moving shadowing
    channel_process=scenarios.spec("rayleigh_block", rho=0.9,
                                   shadowing_rho=0.8),
    # flash-crowd onboarding: bursts of arrivals at the same long-run rate
    arrival_process=scenarios.spec("mmpp", burst=8.0, stay=0.8),
    # a fifth of the fleet drops each period and takes a while to return;
    # one anchor client per service stays reachable
    churn_process=scenarios.spec("gilbert", p_drop=0.2, p_return=0.3,
                                 always_keep=1),
)

print(f"{len(SEEDS)} seeds x {calm.max_periods} periods, "
      f"{calm.n_services_total} services, {calm.rounds_required} rounds each\n")
print(f"{'policy':>8s} | {'calm dur':>9s} | {'stormy dur':>10s} | "
      f"{'ratio':>6s} | {'avail clients':>13s} | stalls")
print("-" * 72)

for pol in simulator.POLICIES:
    rows = {}
    for label, cfg in (("calm", calm), ("stormy", stormy)):
        out = simulator.run_batch(dataclasses.replace(cfg, policy=pol), SEEDS)
        rows[label] = out
    calm_d = float(np.mean(rows["calm"]["avg_duration"]))
    storm_d = float(np.mean(rows["stormy"]["avg_duration"]))
    hist = rows["stormy"]["history"]
    busy = hist["n_active"] > 0
    # churn-visible fleet: available clients per active service
    avail = float(np.sum(hist["n_clients"][busy])
                  / max(np.sum(hist["n_active"][busy]), 1))
    calm_h = rows["calm"]["history"]
    calm_busy = calm_h["n_active"] > 0
    calm_avail = float(np.sum(calm_h["n_clients"][calm_busy])
                       / max(np.sum(calm_h["n_active"][calm_busy]), 1))
    # periods where arrived-but-empty services made zero progress
    stalls = int(np.sum(busy & (hist["freq_sum"] == 0.0)))
    unfinished = int(np.sum(~rows["stormy"]["finished"]))
    note = f"{stalls}" + (f", {unfinished} hit max_periods" if unfinished else "")
    print(f"{pol:>8s} | {calm_d:9.2f} | {storm_d:10.2f} | "
          f"{storm_d / max(calm_d, 1e-9):5.2f}x | "
          f"{avail:5.1f} (vs {calm_avail:4.1f}) | {note}")

print("""
Same long-run arrival rate, same average channel, same enrolled fleet --
only the temporal structure changed.  Two opposing forces emerge: Gilbert
churn thins each synchronous round (fewer available clients -> shorter
rounds), while correlated fades and arrival bursts pile services onto bad
channels at the same time.  The optimizing policies (coop/selfish/es/pp)
net out *faster* by re-solving around the surviving clients each period;
equal-client -- the one policy with no intra-service optimization -- is the
one that degrades.  None of this is visible under i.i.d. evaluation.""")
