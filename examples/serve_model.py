"""Example 3: batched serving with a KV cache (prefill + decode loop).

Runs a reduced gemma3-1b (sliding-window + global attention interleave)
through the production serve path: prefill builds the cache, then tokens
decode one at a time against it.

  PYTHONPATH=src python examples/serve_model.py
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "gemma3-1b", "--batch", "4",
                "--prompt-len", "64", "--gen", "24"]
    serve.main()
