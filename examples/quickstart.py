"""Quickstart: one allocation period, end to end.

Builds the paper's representative 5-service scenario, solves the intra- and
inter-service bandwidth allocation under all policies, and prints the
resulting FL round frequencies -- the whole core contribution in ~30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import auction, baselines, disba, fairness, intra, network

svc, meta = network.table1_service_set(jax.random.key(0))
B, T = network.B_TOTAL_MHZ, network.PERIOD_S
print(f"5 FL services, clients = {meta['client_counts'].tolist()}, "
      f"B = {B} MHz, period T = {T}s\n")

# --- cooperative: DISBA (Algorithm 1) -------------------------------------
res = disba.disba(svc, B, gamma=0.1, eps=1e-4)
print(f"[coop/DISBA]    lambda*={float(res.lam):.4f}  "
      f"iterations={int(res.iterations)}")
print(f"  bandwidth ratios: {jnp.round(res.b / B, 3).tolist()}")
print(f"  rounds/period:    {jnp.round(res.f * T, 1).tolist()}\n")

# --- selfish: fairness-adjusted multi-bid auction (M=5, alpha=0.5) ---------
ar = auction.run_auction(svc, B, n_bids=5, alpha_fair=0.5)
print(f"[selfish/auction] zeta={float(ar.price):.4f}")
print(f"  bandwidth ratios: {jnp.round(ar.b / B, 3).tolist()}")
print(f"  rounds/period:    {jnp.round(ar.f * T, 1).tolist()}")
print(f"  provider utilities: {jnp.round(ar.utilities, 3).tolist()}\n")

# --- benchmarks -------------------------------------------------------------
for name, fn in [("equal-client", baselines.equal_client),
                 ("equal-service", baselines.equal_service),
                 ("proportional", baselines.proportional)]:
    b, f = fn(svc, B)
    obj = float(jnp.sum(jnp.log1p(f)))
    print(f"[{name:13s}] objective={obj:.4f}  rounds/period="
          f"{jnp.round(f * T, 1).tolist()}")
obj_coop = float(jnp.sum(jnp.log1p(res.f)))
print(f"[coop         ] objective={obj_coop:.4f}  <- optimal by construction")

# --- intra-service split for service 0 --------------------------------------
alloc = intra.client_allocation(svc, res.b)
print(f"\nper-client MHz for service 1 (first 10 clients): "
      f"{jnp.round(alloc[0, :10], 4).tolist()}")
print("all clients finish simultaneously (Eq. 6) -- that's the water-fill.")
