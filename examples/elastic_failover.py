"""Example 4: fault tolerance + elasticity, both layers.

1. Device layer: a training loop is killed mid-run (injected failure); the
   restart resumes from the newest COMMIT-complete checkpoint and reaches a
   bit-identical final state.
2. Paper layer: a new FL service arrives mid-simulation; the period re-solve
   re-allocates bandwidth without disturbing the survivors -- the paper's own
   elasticity mechanism.
3. Mesh layer: losing 16 of 256 devices re-factors the mesh (the plan shows
   which parallelism axis absorbs the change).

  PYTHONPATH=src python examples/elastic_failover.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import network, policy
from repro.core.types import mask_inactive
from repro.distributed import elastic, fault

# ---- 1. crash + resume ------------------------------------------------------
print("=== 1. checkpoint/restart ===")
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, keep=2)

    def step(state, t):
        key = jax.random.fold_in(jax.random.key(0), t)
        return {"w": state["w"] * 0.99 + 0.01 * jax.random.normal(key, (4,))}

    init = {"w": jnp.zeros((4,))}
    try:
        fault.resumable_loop(step, init, 30, mgr,
                             fault.RestartPolicy(save_every=10), fail_at=23)
    except RuntimeError as e:
        print(f"  crash injected: {e}")
    final = fault.resumable_loop(step, init, 30, mgr,
                                 fault.RestartPolicy(save_every=10))
    clean = init
    for t in range(30):
        clean = step(clean, t)
    match = np.allclose(np.asarray(final["w"]), np.asarray(clean["w"]))
    print(f"  resumed state identical to uninterrupted run: {match}")

# ---- 2. service arrival = the paper's elasticity ---------------------------
# Fixed-capacity style (the scan simulator's device): ONE capacity-6
# ServiceSet; the arrival is a mask flip on slot 5, so the re-solve reuses
# the very same compiled allocation step -- no shape change, no retrace.
print("\n=== 2. service arrival re-allocation (mask flip, zero retrace) ===")
svc, _ = network.sample_services(jax.random.key(1), 6, k_max=30)
B = network.B_TOTAL_MHZ
coop = jax.jit(policy.get_policy("coop"))
b5, _ = coop(mask_inactive(svc, jnp.array([1, 1, 1, 1, 1, 0], bool)), B)
b6, _ = coop(svc, B)
print(f"  5 active:   ratios {jnp.round(b5 / B, 3).tolist()}")
print(f"  +1 arrival: ratios {jnp.round(b6 / B, 3).tolist()}")
print("  survivors shrink proportionally; no service starves (log barrier).")

# ---- 3. device loss -> re-mesh ---------------------------------------------
print("\n=== 3. elastic re-mesh after device loss ===")
for lost in (0, 16, 4):
    plan = elastic.plan_service_remesh(256, 256 - lost)
    print(f"  256 -> {256 - lost} devices: {plan['after']} "
          f"(model-parallel changed: {plan['model_parallel_changed']})")
