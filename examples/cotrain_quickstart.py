"""Co-training quickstart: allocation-paced FedAvg, accuracy vs wall-clock.

Couples the multi-period bandwidth simulator to real federated training
(`repro.fl.cotrain`): two allocation policies pace the *same* arriving FL
services (same seeds, channels, arrivals), each service carries a real
model through the episode, and the printout compares the accuracy each
policy buys per simulated second.  Finishes with the live FLService
bookkeeping and checkpoints the co-trained per-service models with the
fault-tolerant CheckpointManager.

  PYTHONPATH=src python examples/cotrain_quickstart.py
"""
import tempfile

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import network
from repro.fl import cotrain, simulator

SEEDS = [0, 1]

# A scarce band (2 MHz) and compute-bounded clients: the allocator decides
# the training pace, and the per-period round grant stays under the cap.
net = network.NetworkConfig(total_bandwidth_mhz=2.0, period_s=4.0,
                            mean_clients=10.0, var_clients=12.0,
                            t_local_lo=0.15, t_local_hi=0.3)
train = cotrain.TrainSpec(vocab=32, seq_len=8, batch_size=4, eval_batch=32,
                          rounds_cap=14, client_lr=0.5)

print(f"{len(SEEDS)} seeds, 4 services, 36 FedAvg rounds each, "
      f"B={net.total_bandwidth_mhz} MHz, period={net.period_s}s\n")

results = {}
for pol in ("coop", "es"):
    cfg = simulator.SimConfig(policy=pol, n_services_total=4,
                              rounds_required=36, p_arrive=1.0,
                              max_periods=50, k_max=26,
                              mean_clients=10.0, var_clients=12.0)
    results[pol] = cotrain.run_cotrain_batch(cfg, train, SEEDS, net)

print(f"{'time [s]':>9s} | " + " | ".join(f"{p:>10s} acc" for p in results))
time_s = results["coop"]["time_s"]
acc = {p: np.asarray(r["history"]["acc"]).mean(axis=(0, 2))
       for p, r in results.items()}
for t in range(3, len(time_s), 4):
    print(f"{time_s[t]:9.0f} | "
          + " | ".join(f"{acc[p][t]:14.3f}" for p in results))

print("\nper-policy summary:")
for pol, out in results.items():
    print(f"  {pol:5s} avg_duration={float(np.mean(out['avg_duration'])):.2f} "
          f"periods, clipped_rounds={int(np.sum(out['clipped_rounds']))}, "
          f"finished={bool(np.all(out['finished']))}")

print("\nFLService bookkeeping (coop, seed 0) -- driven by the episode:")
for svc in results["coop"]["services"][0]:
    print(f"  service {svc.service_id}: {svc.n_clients} clients, arrived "
          f"period {svc.arrived_period}, {svc.rounds_done}/"
          f"{svc.rounds_required} rounds over {svc.periods_active} periods, "
          f"finished={svc.finished}")

# The co-trained models are the product: checkpoint the stacked per-service
# params (seed 0) with the crash-safe manager.
with tempfile.TemporaryDirectory() as ckpt_dir:
    mgr = CheckpointManager(ckpt_dir, keep=1)
    out = results["coop"]
    params0 = np.asarray(out["params"])[0]
    step = int(out["periods"][0])
    mgr.save(step, {"bigram_table": params0},
             extra={"policy": "coop", "durations":
                    [int(d) for d in out["durations"][0]]})
    restored, extra = mgr.restore(step, {"bigram_table": params0})
    assert np.array_equal(restored["bigram_table"], params0)
    print(f"\ncheckpointed co-trained params at period {step} "
          f"(policy={extra['policy']}) and restored bit-exact")
